"""Tests for beyond-core extensions: mixed-type schema, CTGAN baseline,
non-uniform timestep schedule."""
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.core.forest_flow import ForestGenerativeModel
from repro.core.mixed_types import TabularSchema


def test_schema_encode_decode_roundtrip():
    rng = np.random.default_rng(0)
    n = 200
    X = np.stack([
        rng.normal(size=n),                       # continuous
        rng.integers(0, 5, n).astype(float),      # integer
        rng.choice([10.0, 20.0, 30.0], n),        # categorical
    ], axis=1)
    schema = TabularSchema(cat_cols=[2], int_cols=[1]).fit(X)
    Z = schema.encode(X)
    assert Z.shape == (n, 2 + 3)  # 2 numeric + 3 one-hot
    back = schema.decode(Z)
    np.testing.assert_allclose(back, X, rtol=1e-6)


def test_schema_decode_snaps_types():
    rng = np.random.default_rng(1)
    X = np.stack([rng.normal(size=50),
                  rng.integers(0, 3, 50).astype(float),
                  rng.choice([1.0, 2.0], 50)], axis=1)
    schema = TabularSchema(cat_cols=[2], int_cols=[1]).fit(X)
    Z = schema.encode(X) + 0.2 * rng.normal(size=(50, 4))  # generated-ish
    back = schema.decode(Z)
    assert set(np.unique(back[:, 2])) <= {1.0, 2.0}
    assert np.all(back[:, 1] == np.round(back[:, 1]))
    assert back[:, 1].min() >= 0 and back[:, 1].max() <= 2


def test_forest_flow_with_mixed_schema_end_to_end():
    rng = np.random.default_rng(2)
    n = 300
    cont = rng.normal(size=n)
    cat = (cont > 0).astype(float) * 10 + 10      # correlated categorical
    X = np.stack([cont, cat], axis=1)
    schema = TabularSchema(cat_cols=[1]).fit(X)
    Z = schema.encode(X)
    fcfg = ForestConfig(n_t=8, duplicate_k=10, n_trees=20, max_depth=3,
                        n_bins=32, reg_lambda=1.0)
    m = ForestGenerativeModel(fcfg).fit(Z, seed=0)
    G, _ = m.generate(n, seed=1)
    back = schema.decode(G)
    assert set(np.unique(back[:, 1])) <= {10.0, 20.0}
    # correlation survives the pipeline: cat==20 rows have higher cont
    hi = back[back[:, 1] == 20.0, 0]
    lo = back[back[:, 1] == 10.0, 0]
    assert hi.mean() > lo.mean() + 0.5


def test_ctgan_baseline_trains_and_generates():
    from repro.core.ctgan import CTGANBaseline
    rng = np.random.default_rng(3)
    X = np.concatenate([
        np.array([-2.0, 1.0]) + 0.3 * rng.normal(size=(150, 2)),
        np.array([2.0, -1.0]) + 0.3 * rng.normal(size=(150, 2)),
    ]).astype(np.float32)
    y = np.repeat([0, 1], 150)
    m = CTGANBaseline(steps=400, batch=64).fit(X, y, seed=0)
    G, yg = m.generate(200, seed=1)
    assert G.shape == (200, 2)
    assert np.all(np.isfinite(G))
    # conditional means move in the right direction per class
    assert G[yg == 0, 0].mean() < G[yg == 1, 0].mean()


def test_cosine_schedule_grid_and_generation():
    from repro.core import interpolants as itp
    ts = np.asarray(itp.timesteps("flow", 10, 1e-3, "cosine"))
    assert ts[0] == 0.0 and abs(ts[-1] - 1.0) < 1e-6
    # denser near zero: first gap < last gap
    assert (ts[1] - ts[0]) < (ts[-1] - ts[-2])
    rng = np.random.default_rng(4)
    X = (np.array([1.0, -1.0]) + 0.4 * rng.normal(size=(300, 2))).astype(
        np.float32)
    fcfg = ForestConfig(n_t=10, duplicate_k=10, n_trees=15, max_depth=3,
                        n_bins=32, reg_lambda=1.0, t_schedule="cosine")
    m = ForestGenerativeModel(fcfg).fit(X, seed=0)
    G, _ = m.generate(300, seed=1)
    np.testing.assert_allclose(G.mean(0), [1.0, -1.0], atol=0.3)
