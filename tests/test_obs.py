"""Tests for repro.obs: metrics registry, Prometheus exporter, tracer —
and the PR-8 contract that /statz and /metrics are views over the same
instruments and can never disagree."""
import json
import re
import threading
import urllib.request

import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.obs import (CONTENT_TYPE, MetricsRegistry, SlowLog, Tracer,
                       render_prometheus)
from repro.obs.metrics import DEFAULT_BUCKETS
from repro.serving import AdmissionController, ModelRegistry
from repro.tabgen import fit_artifacts


# ---------------------------------------------------------------------------
# MetricsRegistry: instruments, schema, thread safety
# ---------------------------------------------------------------------------

def test_counter_basics_and_label_sum():
    reg = MetricsRegistry()
    c = reg.counter("requests", "Requests", ("tenant", "outcome"))
    c.inc(3, tenant="a", outcome="ok")
    c.inc(2, tenant="b", outcome="ok")
    c.inc(1, tenant="b", outcome="err")
    assert c.get(tenant="a", outcome="ok") == 3
    assert c.get(tenant="z", outcome="ok") == 0      # untouched series
    assert c.sum() == 6
    assert c.sum(tenant="b") == 3
    assert c.sum(outcome="ok") == 5
    with pytest.raises(ValueError):
        c.inc(-1, tenant="a", outcome="ok")          # monotonic
    with pytest.raises(ValueError):
        c.inc(1, tenant="a")                         # missing label


def test_counter_reset_drops_matching_series():
    reg = MetricsRegistry()
    c = reg.counter("events", labelnames=("model", "event"))
    c.inc(5, model="m1", event="acquires")
    c.inc(7, model="m2", event="acquires")
    c.reset(model="m1")
    assert c.get(model="m1", event="acquires") == 0
    assert c.get(model="m2", event="acquires") == 7


def test_gauge_set_inc_dec_and_ratchet():
    reg = MetricsRegistry()
    g = reg.gauge("inflight")
    g.set(2)
    g.inc()
    g.dec(3)
    assert g.get() == 0
    hi = reg.gauge("inflight_max")
    hi.set_max(3)
    hi.set_max(1)                                    # ratchet: no decrease
    assert hi.get() == 3


def test_registry_get_or_create_and_schema_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("rows", "Rows", ("tenant",))
    assert reg.counter("rows", "Rows", ("tenant",)) is a
    with pytest.raises(ValueError):
        reg.gauge("rows")                            # type mismatch
    with pytest.raises(ValueError):
        reg.counter("rows", labelnames=("sampler",))  # label mismatch
    with pytest.raises(ValueError):
        reg.counter("bad name")                      # invalid name


def test_histogram_bucket_edges_are_le_inclusive():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    h.observe(0.1)     # exactly at a bound: le="0.1" (inclusive)
    h.observe(0.5)     # -> le="1.0"
    h.observe(2.0)     # above the last finite bound -> only +Inf
    s = h.get()
    assert s["buckets"] == [1, 1]
    assert s["count"] == 3
    assert s["sum"] == pytest.approx(2.6)
    with pytest.raises(ValueError):
        reg.histogram("lat2", buckets=(1.0, 0.1))    # unsorted
    with pytest.raises(ValueError):
        reg.histogram("lat3", buckets=(0.1, float("inf")))  # +Inf implicit


def test_registry_thread_safety_exact_counts():
    reg = MetricsRegistry()
    c = reg.counter("hits", labelnames=("worker",))
    h = reg.histogram("work", buckets=(0.5,))
    n_threads, n_iter = 8, 500

    def worker(i):
        for _ in range(n_iter):
            c.inc(1, worker=str(i % 2))
            h.observe(0.25)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.sum() == n_threads * n_iter
    assert h.count() == n_threads * n_iter
    assert h.get()["buckets"] == [n_threads * n_iter]


def test_snapshot_is_one_consistent_cut():
    reg = MetricsRegistry()
    c = reg.counter("paired_a")
    d = reg.counter("paired_b")
    stop = threading.Event()

    def mutate():
        while not stop.is_set():
            with reg.lock:       # writers keep a+b in lockstep
                c.inc()
                d.inc()

    t = threading.Thread(target=mutate)
    t.start()
    try:
        for _ in range(200):
            snap = reg.snapshot()
            assert snap["paired_a"]["values"].get((), 0.0) == \
                snap["paired_b"]["values"].get((), 0.0)
    finally:
        stop.set()
        t.join()


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?P<labels>\{[^}]*\})? (?P<value>\S+)$')


def _parse_prom(text):
    """{(name, frozenset(label pairs)): float} over all sample lines."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        assert m, f"unparseable exposition line: {line!r}"
        labels = frozenset(
            re.findall(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"',
                       m.group("labels") or ""))
        out[(m.group("name"), labels)] = float(m.group("value"))
    return out


def test_render_counter_total_suffix_and_integer_format():
    reg = MetricsRegistry()
    reg.counter("rows", "Rows served", ("tenant",)).inc(7, tenant="a")
    text = render_prometheus(reg)
    assert "# HELP rows_total Rows served" in text
    assert "# TYPE rows_total counter" in text
    assert 'rows_total{tenant="a"} 7\n' in text      # bare int, no 7.0


def test_render_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "Latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    parsed = _parse_prom(render_prometheus(reg))
    assert parsed[("lat_bucket", frozenset({("le", "0.1")}))] == 1
    assert parsed[("lat_bucket", frozenset({("le", "1")}))] == 2  # cumulative
    assert parsed[("lat_bucket", frozenset({("le", "+Inf")}))] == 3
    assert parsed[("lat_count", frozenset())] == 3
    assert parsed[("lat_sum", frozenset())] == pytest.approx(2.55)


def test_render_escapes_label_values_and_help():
    reg = MetricsRegistry()
    reg.counter("c", 'help with \\ and\nnewline', ("k",)).inc(
        1, k='quo"te\\back\nline')
    text = render_prometheus(reg)
    assert r'# HELP c_total help with \\ and\nnewline' in text
    assert r'c_total{k="quo\"te\\back\nline"} 1' in text
    assert "\nnewline" not in text.replace(r"\nnewline", "")


def test_render_unions_registries_and_rejects_collisions():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("serving_rows").inc(1)
    b.counter("admission_rows").inc(2)
    parsed = _parse_prom(render_prometheus(a, b, a))   # dup registry: ok
    assert parsed[("serving_rows_total", frozenset())] == 1
    assert parsed[("admission_rows_total", frozenset())] == 2
    c = MetricsRegistry()
    c.counter("serving_rows").inc(9)
    with pytest.raises(ValueError):
        render_prometheus(a, c)       # same family from distinct registries


def test_every_exposed_name_is_prometheus_valid():
    """All instruments the repo registers expose legal family names."""
    reg = MetricsRegistry()
    AdmissionController(metrics=reg)
    text = render_prometheus(reg)
    for line in text.splitlines():
        if line.startswith("# TYPE "):
            name = line.split()[2]
            assert re.match(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$", name)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_span_nesting_links_parent_and_times_body():
    tr = Tracer()
    with tr.span("outer", batch=1) as outer:
        with tr.span("inner") as inner:
            pass
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    done = tr.spans()
    assert [s.name for s in done] == ["inner", "outer"]  # inner ends first
    assert outer.duration_s >= inner.duration_s >= 0.0
    assert tr.durations("inner") == [inner.duration_s]
    assert tr.spans(prefix="out")[0] is outer


def test_span_ring_evicts_oldest():
    tr = Tracer(capacity=3)
    for i in range(5):
        with tr.span("s", i=i):
            pass
    kept = tr.spans(name="s")
    assert len(kept) == 3
    assert [s.attrs["i"] for s in kept] == [2, 3, 4]


def test_cross_thread_span_and_end_attrs():
    tr = Tracer()
    sp = tr.start("serve.device", rows=64)
    out = {}

    def resolver():
        out["dt"] = sp.end(outcome="ok")

    t = threading.Thread(target=resolver)
    t.start()
    t.join()
    assert sp.attrs["outcome"] == "ok"
    assert out["dt"] == pytest.approx(sp.duration_s)
    assert sp.end() == out["dt"]          # idempotent: same duration back
    assert len(tr.spans(name="serve.device")) == 1   # recorded once


def test_span_jsonl_export(tmp_path):
    tr = Tracer()
    with tr.span("a", k="v"):
        pass
    path = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(str(path)) == 1
    rec = json.loads(path.read_text().splitlines()[0])
    assert rec["name"] == "a" and rec["attrs"] == {"k": "v"}
    assert rec["duration_s"] >= 0.0 and rec["parent_id"] is None


def test_span_jsonl_export_append_vs_truncate(tmp_path):
    """Default export truncates (a fresh snapshot of the ring); append=True
    accumulates — the mode periodic exporters and bench artifacts use."""
    tr = Tracer()
    with tr.span("a"):
        pass
    path = str(tmp_path / "spans.jsonl")
    assert tr.export_jsonl(path) == 1
    assert tr.export_jsonl(path) == 1            # truncate: same 1 line
    assert len(open(path).read().splitlines()) == 1
    assert tr.export_jsonl(path, append=True) == 1
    lines = open(path).read().splitlines()
    assert len(lines) == 2                       # append: accumulates
    assert all(json.loads(ln)["name"] == "a" for ln in lines)


def test_trace_index_returns_request_timeline_sorted():
    """tracer.trace(id) stitches the queue span (trace_id) and the device
    span (links) into one timeline, ordered by start time."""
    tr = Tracer()
    dev = tr.start("serve.device", links=("r1", "r2"), t_start=5.0)
    q1 = tr.start("serve.queue", trace_id="r1", t_start=1.0)
    q1.end()
    dev.end()
    tl = tr.trace("r1")
    assert [s.name for s in tl] == ["serve.queue", "serve.device"]
    assert tl[0] is q1 and tl[1] is dev
    assert [s.name for s in tr.trace("r2")] == ["serve.device"]
    assert tr.trace("nope") == []


def test_trace_index_evicts_with_ring():
    """Ring eviction drops the by-trace index too — an evicted request id
    resolves to nothing rather than leaking span references forever."""
    tr = Tracer(capacity=2)
    for i in range(4):
        with tr.span("s", trace_id=f"r{i}"):
            pass
    assert tr.trace("r0") == [] and tr.trace("r1") == []
    assert len(tr.trace("r2")) == 1 and len(tr.trace("r3")) == 1


def test_slow_log_always_appends_and_creates_eagerly(tmp_path):
    import os
    path = str(tmp_path / "slow.jsonl")
    slow = SlowLog(path, threshold_s=0.5)
    assert os.path.exists(path)                  # eager create: empty file
    assert open(path).read() == ""               # "no slow requests" state
    slow.record({"request_id": "b", "latency_s": 0.9})
    assert slow.written == 1
    # a second SlowLog on the same path appends — restart-safe capture
    slow2 = SlowLog(path, threshold_s=0.5)
    slow2.record({"request_id": "c", "latency_s": 2.0})
    recs = [json.loads(ln) for ln in open(path).read().splitlines()]
    assert [r["request_id"] for r in recs] == ["b", "c"]
    with pytest.raises(ValueError):
        SlowLog(str(tmp_path / "x.jsonl"), threshold_s=-1.0)


# ---------------------------------------------------------------------------
# /metrics over HTTP, reconciled against /statz (the tentpole contract)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def obs_http_plane():
    from repro.launch.serve_http import ServingApp, serve_in_thread
    X, y = two_moons(300, seed=0)
    fcfg = ForestConfig(method="flow", n_t=6, duplicate_k=8, n_trees=10,
                        max_depth=3, n_bins=16, reg_lambda=1.0)
    art = fit_artifacts(X, y, fcfg, seed=0)
    metrics, tracer = MetricsRegistry(), Tracer()
    registry = ModelRegistry(buckets=(64,), metrics=metrics)
    registry.register("moons", art, samplers=("euler",))
    app = ServingApp(registry,
                     AdmissionController(metrics=metrics),
                     metrics=metrics, tracer=tracer)
    httpd, thread = serve_in_thread(app)
    host, port = httpd.server_address[:2]
    yield app, tracer, f"http://{host}:{port}"
    httpd.shutdown()
    httpd.server_close()
    app.stop()
    thread.join(timeout=10)


def _get(url):
    with urllib.request.urlopen(url, timeout=120) as resp:
        return resp.status, dict(resp.headers), resp.read().decode()


def test_http_metrics_reconciles_with_statz(obs_http_plane):
    app, tracer, base = obs_http_plane
    req = urllib.request.Request(
        f"{base}/v1/generate", method="POST",
        data=json.dumps({"model": "moons", "n": 40,
                         "tenant": "t1"}).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=300) as resp:
        body = json.load(resp)
    assert np.asarray(body["rows"]).shape == (40, 2)

    status, headers, text = _get(f"{base}/metrics")
    assert status == 200
    assert headers["Content-Type"] == CONTENT_TYPE
    parsed = _parse_prom(text)

    status, _, statz_text = _get(f"{base}/statz")
    assert status == 200
    statz = json.loads(statz_text)

    sched = statz["scheduler"]
    def fam(name):
        return sum(v for (n, _), v in parsed.items() if n == name)
    assert fam("serving_requests_total") == sched["requests"] == 1
    assert fam("serving_rows_total") == sched["rows"] == 40
    assert parsed[("serving_device_seconds_count",
                   frozenset({("sampler", "euler")}))] == sched["batches"]
    assert fam("serving_device_seconds_sum") == \
        pytest.approx(sched["device_s"])
    assert fam("serving_queue_wait_seconds_sum") == \
        pytest.approx(sched["queue_wait_s"])
    adm = statz["admission"]["tenants"]
    assert parsed[("admission_requests_total",
                   frozenset({("tenant", "t1"),
                              ("outcome", "admitted")}))] == \
        adm["t1"]["admitted"]
    assert parsed[("registry_models", frozenset())] == 1

    # queue-wait and device-time come from spans, not hand-stamped deltas
    qspans = tracer.spans(name="serve.queue")
    dspans = tracer.spans(name="serve.device")
    assert len(qspans) == 1 and len(dspans) == sched["batches"]
    assert sum(s.duration_s for s in qspans) == \
        pytest.approx(sched["queue_wait_s"])
    assert sum(s.duration_s for s in dspans) == \
        pytest.approx(sched["device_s"])
    assert qspans[0].attrs["tenant"] == "t1"


def test_http_metrics_404_free_and_statz_shape(obs_http_plane):
    _, _, base = obs_http_plane
    status, _, text = _get(f"{base}/statz")
    body = json.loads(text)
    assert {"scheduler", "admission", "registry"} <= set(body)
    assert {"requests", "rows", "gen_s", "queue_wait_s", "device_s",
            "batches", "per_sampler", "per_tenant"} <= set(body["scheduler"])


# ---------------------------------------------------------------------------
# offline dump CLI
# ---------------------------------------------------------------------------

def test_metrics_dump_cli_demo(capsys, tmp_path):
    from repro.launch.metrics import main
    main(["--demo"])
    out = capsys.readouterr().out
    parsed = _parse_prom(out)
    assert parsed[("demo_requests_total", frozenset({("tenant", "a")}))] == 3
    assert parsed[("demo_latency_seconds_bucket",
                   frozenset({("le", "+Inf")}))] == 4
    path = tmp_path / "m.prom"
    main(["--demo", "--out", str(path)])
    assert _parse_prom(path.read_text().strip() + "\n")


def test_default_buckets_are_sane():
    assert list(DEFAULT_BUCKETS) == sorted(set(DEFAULT_BUCKETS))
    assert DEFAULT_BUCKETS[0] <= 0.001 and DEFAULT_BUCKETS[-1] >= 10
