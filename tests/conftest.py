"""Shared fixtures for the test suite."""
import pytest


@pytest.fixture
def recompile_budget():
    """Context-manager factory pinning jit compile counts over a region:
    ``with recompile_budget(0): server.generate(...)`` fails on any compile
    or tracing activity. See :mod:`repro.analysis.runtime`."""
    from repro.analysis.runtime import recompile_budget as _recompile_budget
    return _recompile_budget
