"""Training substrate: optimizer semantics, checkpoint/restart, data
determinism, loss-goes-down integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency; see README + the shim module
    from _hypothesis_fallback import given, settings, strategies as st

from repro.config import TrainConfig
from repro.configs import get_arch
from repro.data.tokens import FastTokenStream
from repro.train import checkpoint as ckpt
from repro.train.loop import train
from repro.train.optim import adamw_update, init_opt_state, lr_schedule


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=1000,
                       weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, m = adamw_update(g, opt, params, tcfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(jnp.int32(1), tcfg)) < 0.2
    peak = float(lr_schedule(jnp.int32(10), tcfg))
    assert peak == pytest.approx(1.0, rel=1e-3)
    assert float(lr_schedule(jnp.int32(100), tcfg)) < 0.2


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_grad_clip_bounds_update_norm(seed):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    g = {"w": jnp.asarray((1000 * rng.normal(size=(8,))).astype(np.float32))}
    tcfg = TrainConfig(learning_rate=0.1, grad_clip=1.0, weight_decay=0.0,
                       warmup_steps=0, total_steps=10)
    opt = init_opt_state(params)
    _, opt2, m = adamw_update(g, opt, params, tcfg)
    clipped = jax.tree_util.tree_map(lambda a: a * jnp.minimum(
        1.0, 1.0 / jnp.maximum(m["grad_norm"], 1e-9)), g)
    assert float(jnp.linalg.norm(clipped["w"])) <= 1.0 + 1e-4


def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones((4,)), {"c": jnp.int32(7)}]}
    ckpt.save(str(tmp_path), 5, tree)
    ckpt.save(str(tmp_path), 10, jax.tree_util.tree_map(lambda x: x * 2, tree))
    assert ckpt.latest_step(str(tmp_path)) == 10
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  2 * np.arange(6).reshape(2, 3))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((4,))})


def test_uncommitted_checkpoint_ignored(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((2,))})
    # simulate a crash mid-write: step dir without COMMITTED marker
    bad = tmp_path / "step_2"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_data_stream_deterministic_and_stateless():
    s1 = FastTokenStream(1000, 16, 4, seed=3)
    s2 = FastTokenStream(1000, 16, 4, seed=3)
    b1 = s1.batch_at(17)
    # recompute batch 17 without computing 0..16 (stateless property)
    b2 = s2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_train_resume_is_exact(tmp_path):
    """20 straight steps == 10 steps + crash + resume for 10 more."""
    cfg = get_arch("smollm-135m", reduced=True)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=20,
                       remat_policy="none", seed=0)
    stream = FastTokenStream(cfg.vocab, 16, 4, seed=0)
    data_fn = stream.batch_at

    p_a, _, hist_a = train(cfg, tcfg, data_fn, steps=20, log_every=20,
                           log_fn=lambda *_: None)
    d1 = tmp_path / "run_b"
    train(cfg, tcfg, data_fn, steps=10, ckpt_dir=str(d1), ckpt_every=10,
          log_every=20, log_fn=lambda *_: None)
    p_b, _, hist_b = train(cfg, tcfg, data_fn, steps=20, ckpt_dir=str(d1),
                           ckpt_every=10, log_every=20, log_fn=lambda *_: None)
    leaves_a = jax.tree_util.tree_leaves(p_a)
    leaves_b = jax.tree_util.tree_leaves(p_b)
    for a, b in zip(leaves_a, leaves_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-4)


def test_grad_accum_matches_full_batch():
    from repro.train.loop import make_train_step
    cfg = get_arch("smollm-135m", reduced=True)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10,
                       remat_policy="none", grad_clip=0.0, weight_decay=0.0)
    from repro.models import lm
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    stream = FastTokenStream(cfg.vocab, 16, 8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    s1 = make_train_step(cfg, tcfg, accum=1)
    s2 = make_train_step(cfg, tcfg, accum=2)
    # steps donate their inputs; give each call its own copy
    copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)
    p1, _, m1 = s1(copy(params), init_opt_state(params), batch)
    p2, _, m2 = s2(copy(params), init_opt_state(params), batch)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-5)
