"""Minimal stand-in for the optional ``hypothesis`` dev dependency.

The container image does not ship ``hypothesis``; without a guard the three
property-based test modules crashed the whole suite at collection. With the
real package installed (``pip install hypothesis``) these tests run under
the genuine engine; otherwise this shim runs each ``@given`` test over the
strategy bounds plus deterministic pseudo-random draws — weaker than
hypothesis (no shrinking, no database), but the invariants still execute.

Only the surface the suite uses is implemented: ``strategies.integers``,
``@given`` over positional strategies, and ``@settings(max_examples=...,
deadline=...)``.
"""
from __future__ import annotations

import random


class _IntStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def examples(self, n: int, rng: random.Random):
        out = [self.lo, self.hi]
        while len(out) < n:
            out.append(rng.randint(self.lo, self.hi))
        return out[:n]


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntStrategy:
        return _IntStrategy(min_value, max_value)


def settings(max_examples: int = 5, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats: _IntStrategy):
    def deco(fn):
        # NB: no functools.wraps — pytest must see a zero-arg signature or
        # it would try to resolve the strategy parameters as fixtures
        def wrapper():
            # _max_examples lands on `wrapper` when @settings is outermost,
            # on `fn` when the decorators are applied the other way round
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 5))
            rng = random.Random(0)
            cols = [s.examples(n, rng) for s in strats]
            for vals in zip(*cols):
                fn(*vals)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
