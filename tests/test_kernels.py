"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency; see README + the shim module
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.flash_attention.fa_kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hist.hist_kernel import histogram_pallas
from repro.kernels.hist.ref import histogram_ref
from repro.kernels.tree_predict.ref import forest_predict_ref
from repro.kernels.tree_predict.tree_kernel import forest_predict_pallas


# ---------------------------------------------------------------------------
# histogram kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,out,n_nodes,n_bins,rows_block", [
    (256, 3, 1, 1, 8, 128),
    (512, 7, 2, 4, 16, 256),
    (1024, 5, 4, 8, 32, 512),
    (384, 2, 3, 2, 64, 128),
])
def test_hist_kernel_matches_ref(n, p, out, n_nodes, n_bins, rows_block):
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, n_bins, (n, p)), jnp.int32)
    nid = jnp.asarray(rng.integers(0, n_nodes, (n,)), jnp.int32)
    g = jnp.asarray(rng.normal(size=(n, out)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.0, 1.0, n).astype(np.float32))
    s_ref, c_ref = histogram_ref(codes, nid, g, w, n_nodes, n_bins)
    s_pl, c_pl = histogram_pallas(codes, nid, g, w, n_nodes, n_bins,
                                  rows_block=rows_block, interpret=True)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_pl), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(0, 10 ** 6))
def test_hist_kernel_property(n_nodes_pow, out, seed):
    """Property: kernel == oracle for random node/bin assignments."""
    rng = np.random.default_rng(seed)
    n, p, n_bins = 128, 3, 8
    n_nodes = 2 ** n_nodes_pow
    codes = jnp.asarray(rng.integers(0, n_bins, (n, p)), jnp.int32)
    nid = jnp.asarray(rng.integers(0, n_nodes, (n,)), jnp.int32)
    g = jnp.asarray(rng.normal(size=(n, out)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.0, 1.0, n).astype(np.float32))
    s_ref, c_ref = histogram_ref(codes, nid, g, w, n_nodes, n_bins)
    s_pl, c_pl = histogram_pallas(codes, nid, g, w, n_nodes, n_bins,
                                  rows_block=64, interpret=True)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tree predict kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,depth,n_trees,out,rows_block", [
    (128, 4, 3, 5, 1, 64),
    (256, 8, 4, 10, 3, 128),
    (512, 16, 7, 4, 2, 256),
    # odd row counts: the wrapper pads to the block and slices the output
    # (regression — used to hard-crash on `assert n % rows_block == 0`,
    # e.g. a 96-row serving bucket or an oversize exact-size request)
    (96, 4, 3, 5, 1, 64),
    (130, 8, 4, 3, 2, 64),
    (300, 5, 3, 4, 1, 256),
    (1, 3, 3, 2, 1, 256),
])
def test_tree_predict_matches_ref(n, p, depth, n_trees, out, rows_block):
    rng = np.random.default_rng(1)
    h, l = 2 ** depth - 1, 2 ** depth
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    feat = jnp.asarray(rng.integers(0, p, (n_trees, h)), jnp.int32)
    thr = jnp.asarray(rng.normal(size=(n_trees, h)).astype(np.float32))
    leaf = jnp.asarray(rng.normal(size=(n_trees, l, out)).astype(np.float32))
    ref = forest_predict_ref(x, feat, thr, leaf, depth)
    got = forest_predict_pallas(x, feat, thr, leaf, depth,
                                rows_block=rows_block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_tree_predict_matches_trained_forest():
    """The kernel must agree with predictions of an actually-trained forest."""
    from repro.config import ForestConfig
    from repro.forest.binning import edges_with_sentinel, fit_bins, transform
    from repro.forest.boosting import fit_boosted

    rng = np.random.default_rng(2)
    x = rng.normal(size=(512, 5)).astype(np.float32)
    y = (np.sin(x[:, 0]) + x[:, 1]).astype(np.float32)[:, None]
    edges = fit_bins(jnp.asarray(x), 16)
    codes = transform(jnp.asarray(x), edges)
    fcfg = ForestConfig(n_trees=8, max_depth=4, n_bins=16, reg_lambda=1.0)
    res = fit_boosted(codes, jnp.asarray(y), jnp.ones((512,), jnp.float32),
                      edges_with_sentinel(edges), codes, jnp.asarray(y),
                      jnp.ones((512,), jnp.float32), fcfg)
    ref = forest_predict_ref(jnp.asarray(x), res.feat, res.thr_val, res.leaf, 4)
    got = forest_predict_pallas(jnp.asarray(x), res.feat, res.thr_val,
                                res.leaf, 4, rows_block=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,dtype", [
    (1, 2, 2, 128, 128, 32, True, jnp.float32),
    (2, 4, 2, 256, 256, 64, True, jnp.float32),
    (1, 8, 1, 128, 256, 64, False, jnp.float32),
    (2, 4, 4, 128, 128, 64, True, jnp.bfloat16),
    (1, 6, 3, 192, 192, 32, True, jnp.float32),
])
def test_flash_attention_matches_ref(b, hq, hkv, sq, skv, d, causal, dtype):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    ref = attention_ref(q, k, v, causal)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=64, bk=64,
                                 interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_mea():
    """The model-side blocked attention and the kernel agree too."""
    from repro.models.attention import mea_attention
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    a = mea_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    b_ = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4,
                               atol=2e-4)
