"""Tests for the composable tabular-generation API (repro.tabgen)."""
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.data.tabular import two_moons
from repro.eval import metrics as M
from repro.tabgen import (ForestArtifacts, TabularGenerator, fit_artifacts,
                          get_sampler, impute, list_samplers, sample,
                          sample_loop_reference)


@pytest.fixture(scope="module")
def moons_flow_artifacts():
    X, y = two_moons(400, seed=0)
    fcfg = ForestConfig(method="flow", n_t=10, duplicate_k=15, n_trees=30,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    return fit_artifacts(X, y, fcfg, seed=0), X


@pytest.fixture(scope="module")
def moons_diffusion_artifacts():
    X, y = two_moons(400, seed=0)
    fcfg = ForestConfig(method="diffusion", n_t=12, duplicate_k=15,
                        n_trees=30, max_depth=4, n_bins=32, reg_lambda=1.0)
    return fit_artifacts(X, y, fcfg, seed=0), X


def test_registry_contains_stock_samplers():
    assert set(list_samplers()) >= {"euler", "heun", "ddim", "em"}
    assert set(list_samplers("flow")) >= {"euler", "heun"}
    assert set(list_samplers("diffusion")) >= {"ddim", "em"}
    assert get_sampler("em").stochastic and not get_sampler("euler").stochastic
    with pytest.raises(KeyError):
        get_sampler("no_such_solver")


@pytest.mark.parametrize("sampler", ["euler", "heun", "ddim"])
def test_samplers_finite_and_close_to_data(sampler, moons_flow_artifacts,
                                           moons_diffusion_artifacts):
    """euler/heun/ddim all produce finite two-moons samples with sliced-W1
    under a loose bound."""
    if sampler == "ddim":
        art, X = moons_diffusion_artifacts
    else:
        art, X = moons_flow_artifacts
    G, yg = sample(art, 400, sampler=sampler, seed=1)
    assert G.shape == (400, 2)
    assert np.isfinite(G).all()
    assert M.sliced_w1(G, X) < 0.25, sampler


def test_sampler_method_mismatch_raises(moons_flow_artifacts):
    art, _ = moons_flow_artifacts
    with pytest.raises(ValueError):
        sample(art, 16, sampler="ddim")


def test_vmapped_matches_loop_reference_distribution(moons_flow_artifacts):
    """The single-dispatch vmapped solve and the legacy per-class loop target
    the same distribution (keys differ, so compare statistics)."""
    art, X = moons_flow_artifacts
    Gv, yv = sample(art, 400, seed=3)
    Gl, yl = sample_loop_reference(art, 400, seed=3)
    assert Gv.shape == Gl.shape
    np.testing.assert_array_equal(np.sort(yv), np.sort(yl))
    assert abs(M.sliced_w1(Gv, X) - M.sliced_w1(Gl, X)) < 0.1


def test_pad_to_bucket_same_samples(moons_flow_artifacts):
    """Padding the per-class batch to a serving bucket must not change the
    rows that are kept (per-row counter-based noise keys; holds for
    deterministic samplers — ``em`` draws fresh noise each step)."""
    art, _ = moons_flow_artifacts
    G1, y1 = sample(art, 100, seed=5)
    G2, y2 = sample(art, 100, seed=5, pad_to=256)
    np.testing.assert_array_equal(y1, y2)
    np.testing.assert_allclose(G1, G2, rtol=1e-6)


def test_save_load_roundtrip(tmp_path, moons_flow_artifacts):
    """Loaded artifacts generate bit-identical samples under a fixed seed."""
    art, _ = moons_flow_artifacts
    base = art.save(str(tmp_path / "model"))
    art2 = ForestArtifacts.load(base)
    assert art2.config == art.config
    np.testing.assert_array_equal(np.asarray(art.leaf), np.asarray(art2.leaf))
    G1, y1 = sample(art, 200, seed=7)
    G2, y2 = sample(art2, 200, seed=7)
    np.testing.assert_array_equal(G1, G2)
    np.testing.assert_array_equal(y1, y2)


def test_artifacts_is_pytree(moons_flow_artifacts):
    import jax
    art, _ = moons_flow_artifacts
    leaves, treedef = jax.tree_util.tree_flatten(art)
    assert len(leaves) == 8  # device arrays only; classes/counts are aux
    art2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert art2.config == art.config
    np.testing.assert_array_equal(np.asarray(art.feat), np.asarray(art2.feat))
    np.testing.assert_array_equal(art2.classes, art.classes)
    # a whole artifacts object crosses a jit boundary (classes/counts static)
    out = jax.jit(lambda a: a.mins + 1.0)(art)  # jaxlint: disable=JX003 — one-shot pytree-boundary check
    np.testing.assert_allclose(np.asarray(out), np.asarray(art.mins) + 1.0)


def _mixed_dataset(n=400, seed=1):
    rng = np.random.default_rng(seed)
    x_num = rng.normal(size=n)
    x_int = np.round(3 * x_num + rng.normal(size=n)).clip(-5, 5)
    x_cat = (x_num > 0).astype(float) + rng.integers(0, 2, size=n)  # {0,1,2}
    return np.stack([x_num, x_int, x_cat], 1)


def test_tabular_generator_mixed_types_end_to_end(tmp_path):
    X = _mixed_dataset()
    fcfg = ForestConfig(method="flow", n_t=8, duplicate_k=10, n_trees=20,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    gen = TabularGenerator(fcfg, cat_cols=[2], int_cols=[1]).fit(X, seed=0)
    G, _ = gen.generate(300, seed=1)
    assert G.shape == (300, 3)
    # categorical column decodes back onto observed categories
    assert set(np.unique(G[:, 2])) <= set(np.unique(X[:, 2]))
    # integer column is integral and clipped to the observed range
    np.testing.assert_array_equal(G[:, 1], np.round(G[:, 1]))
    assert G[:, 1].min() >= X[:, 1].min() and G[:, 1].max() <= X[:, 1].max()
    # facade save/load round-trip preserves schema + samples
    base = gen.save(str(tmp_path / "mixed"))
    gen2 = TabularGenerator.load(base)
    G2, _ = gen2.generate(300, seed=1)
    np.testing.assert_array_equal(G, G2)
    # imputation through the schema: observed cells untouched, NaNs filled
    Xm = X[:40].copy()
    Xm[:, 0] = np.nan
    filled = gen.impute(Xm, seed=2, refine_rounds=2)
    assert not np.isnan(filled.astype(float)).any()
    np.testing.assert_array_equal(filled[:, 1:], Xm[:, 1:])


def test_tabular_generator_string_categories():
    rng = np.random.default_rng(3)
    cont = rng.normal(size=200)
    X = np.empty((200, 2), object)
    X[:, 0] = cont
    X[:, 1] = np.where(cont > 0, "hi", "lo")
    fcfg = ForestConfig(n_t=4, duplicate_k=4, n_trees=6, max_depth=3,
                        n_bins=16, reg_lambda=1.0)
    gen = TabularGenerator(fcfg, cat_cols=[1]).fit(X, seed=0)
    G, _ = gen.generate(60, seed=1)
    assert set(G[:, 1]) <= {"hi", "lo"}
    # string categories survive the correlation: "hi" rows skew positive
    assert G[G[:, 1] == "hi", 0].astype(float).mean() > \
        G[G[:, 1] == "lo", 0].astype(float).mean()


def test_impute_functional_api(moons_flow_artifacts):
    art, X = moons_flow_artifacts
    Xm = X[:30].copy()
    Xm[:, 1] = np.nan
    # labels from the artifact table so the lut lookup is exercised
    y = np.full(30, np.asarray(art.classes)[0])
    filled = impute(art, Xm, y, seed=3, refine_rounds=2)
    assert not np.isnan(filled).any()
    np.testing.assert_array_equal(filled[:, 0], Xm[:, 0])


def test_forest_server_buckets_and_stats(moons_flow_artifacts):
    from repro.launch.serve_forest import ForestServer
    art, _ = moons_flow_artifacts
    server = ForestServer(art, buckets=(64, 256))
    server.warmup()
    for i, n in enumerate((17, 40, 90, 130)):
        X, y = server.generate(n, seed=i)
        assert X.shape == (n, 2) and len(y) == n
    assert server.stats["requests"] == 4
    assert server.rows_per_sec() > 0


def test_forest_server_microbatches_concurrent_requests(moons_flow_artifacts):
    """submit() coalesces concurrent requests into shared dispatches and the
    locked stats stay consistent under many submitter threads."""
    import threading
    from repro.launch.serve_forest import ForestServer
    art, _ = moons_flow_artifacts
    server = ForestServer(art, buckets=(64, 256),
                          coalesce_window_s=0.05)
    server.warmup()
    sizes = [7, 18, 33, 5, 21, 40, 11, 3]
    futs = [None] * len(sizes)

    def submit(i):
        futs[i] = server.submit(sizes[i])

    threads = [threading.Thread(target=submit, args=(i,))
               for i in range(len(sizes))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i, f in enumerate(futs):
        X, y = f.result(timeout=120)
        assert X.shape == (sizes[i], 2) and len(y) == sizes[i]
        assert np.isfinite(X).all()
    server.stop()
    s = server.stats
    assert s["requests"] == len(sizes)
    assert s["rows"] == sum(sizes)
    # the whole burst fits one coalescing window comfortably -> fewer
    # dispatches than requests, and the two counters reconcile exactly
    assert s["batches"] < len(sizes)
    assert s["coalesced_requests"] == s["requests"] - s["batches"]


def test_forest_server_cancelled_future_does_not_kill_batch(
        moons_flow_artifacts):
    """A request cancelled while queued is dropped; the rest of its batch
    still resolves (regression: set_result on a cancelled Future raised and
    killed the dispatcher thread)."""
    from concurrent.futures import Future
    from repro.launch.serve_forest import ForestServer, _Request
    art, _ = moons_flow_artifacts
    server = ForestServer(art, buckets=(64, 256))
    server.warmup()
    cancelled, live = Future(), Future()
    assert cancelled.cancel()
    server._serve_batch([_Request(10, server.samplers[0], cancelled),
                         _Request(20, server.samplers[0], live)])
    X, y = live.result(timeout=60)
    assert X.shape == (20, 2) and len(y) == 20
    assert server.stats["rows"] == 20  # the cancelled request never ran
    # default coalesce cap tracks the largest bucket (oversize-compile guard)
    assert server.max_coalesce_rows == max(server.buckets)


def test_forest_server_zero_compiles_after_warmup(moons_flow_artifacts,
                                                  recompile_budget):
    """After warmup, served requests (sync and micro-batched) reuse cached
    programs — warmup goes through the same facade path as generate(), so
    the caches can't diverge. Pinned via the recompile_budget fixture."""
    from repro.launch.serve_forest import ForestServer
    art, _ = moons_flow_artifacts
    server = ForestServer(art, buckets=(64, 256))
    server.warmup()

    with recompile_budget(0):
        server.generate(50, seed=11)
        fut = server.submit(23)
        fut.result(timeout=120)
        server.stop()


def test_deprecation_shim_still_works():
    from repro.core.forest_flow import ForestGenerativeModel
    X, y = two_moons(200, seed=0)
    fcfg = ForestConfig(n_t=6, duplicate_k=5, n_trees=10, max_depth=3,
                        n_bins=16, reg_lambda=1.0)
    with pytest.deprecated_call():
        model = ForestGenerativeModel(fcfg)
    model.fit(X, y, seed=0)
    G, yg = model.generate(100, seed=1)
    assert G.shape == (100, 2)
    assert model.forests["leaf"].shape[0] == fcfg.n_t
