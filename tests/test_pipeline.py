"""Pipelined (double-buffered) distributed trainer: bit-exactness against
the serial loop, prefetch-queue backpressure, crash-resume under async
checkpointing, manifest thread-safety, and the int8 single-device plumbing.

Everything here runs in-process on a 1x1 mesh (works on one CPU device)
with one shared ForestConfig, so the lru_cached shard_map program compiles
once for the whole module.
"""
import dataclasses
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.tabgen import PipelineConfig, fit_artifacts
from repro.tabgen import fitting
from repro.train import checkpoint as ckpt

FIELDS = ("feat", "thr_val", "leaf", "best_round", "rounds_run", "val_curve")

FCFG = ForestConfig(n_t=4, duplicate_k=3, n_trees=3, max_depth=2, n_bins=8,
                    reg_lambda=1.0)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(96, 3)).astype(np.float32)
    y = (rng.random(96) > 0.5).astype(np.int64)
    return X, y


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))) for f in FIELDS)


def test_pipelined_bit_exact_vs_serial(data, mesh):
    X, y = data
    serial = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                           ensembles_per_batch=2, pipeline=None)
    piped = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                          ensembles_per_batch=2, pipeline=PipelineConfig())
    assert _equal(serial, piped)
    # sync-checkpoint mode (prefetch only, no writer thread) is also exact
    sync = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                         ensembles_per_batch=2,
                         pipeline=PipelineConfig(async_checkpoint=False))
    assert _equal(serial, sync)


def test_prefetch_backpressure_depths_identical(data, mesh, tmp_path):
    """depth=1 (classic double buffering) and depth=4 bound different
    amounts of in-flight work but must produce identical artifacts and
    identical checkpoint files."""
    X, y = data
    arts = {}
    for depth in (1, 4):
        d = tmp_path / f"depth{depth}"
        arts[depth] = fit_artifacts(
            X, y, FCFG, seed=0, mesh=mesh, ensembles_per_batch=2,
            checkpoint_dir=str(d),
            pipeline=PipelineConfig(prefetch_depth=depth))
        assert fitting.LAST_PIPELINE_STATS["prefetch_depth"] == depth
        assert fitting.LAST_PIPELINE_STATS["n_batches"] == 4
    assert _equal(arts[1], arts[4])
    for b0 in (0, 2, 4, 6):
        a = ckpt.read_batch_npz(str(tmp_path / "depth1"), b0)
        b = ckpt.read_batch_npz(str(tmp_path / "depth4"), b0)
        assert sorted(a) == sorted(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"batch_{b0}.npz[{k}]")


def test_crash_between_writer_flushes_resumes(data, mesh, tmp_path,
                                              monkeypatch):
    """Kill the writer thread after its first durable flush: the manifest
    must stay consistent (only batch 0 committed) and a pipelined resume
    must finish the grid to bit-identical artifacts."""
    X, y = data
    full = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                         ensembles_per_batch=2,
                         checkpoint_dir=str(tmp_path / "full"),
                         pipeline=PipelineConfig())

    crash_dir = str(tmp_path / "crash")
    real = ckpt.write_batch_npz
    calls = {"n": 0}

    def flaky(directory, b0, arrays):
        if calls["n"] >= 1:
            raise OSError("injected crash between writer flushes")
        calls["n"] += 1
        return real(directory, b0, arrays)

    monkeypatch.setattr(ckpt, "write_batch_npz", flaky)
    with pytest.raises(OSError, match="injected crash"):
        fit_artifacts(X, y, FCFG, seed=0, mesh=mesh, ensembles_per_batch=2,
                      checkpoint_dir=crash_dir, pipeline=PipelineConfig())
    monkeypatch.setattr(ckpt, "write_batch_npz", real)

    # only the durably flushed batch is in the manifest
    man = ckpt.GridManifest(crash_dir, fingerprint={})
    with open(man.path) as f:
        committed = json.load(f)["batches"]
    assert committed == [[0, 2]], committed

    resumed = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                            ensembles_per_batch=2, checkpoint_dir=crash_dir,
                            resume=True, pipeline=PipelineConfig())
    assert _equal(full, resumed)
    assert fitting.LAST_PIPELINE_STATS["n_cached"] == 1


def test_serial_checkpoint_resumes_under_pipeline(data, mesh, tmp_path):
    """The execution style is not fingerprinted: a checkpoint written by the
    serial loop resumes under the pipeline (and is fully cache-served)."""
    X, y = data
    d = str(tmp_path / "ck")
    serial = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                           ensembles_per_batch=2, checkpoint_dir=d,
                           pipeline=None)
    piped = fit_artifacts(X, y, FCFG, seed=0, mesh=mesh,
                          ensembles_per_batch=2, checkpoint_dir=d,
                          resume=True, pipeline=PipelineConfig())
    assert _equal(serial, piped)
    assert fitting.LAST_PIPELINE_STATS["n_cached"] == 4


def test_pipeline_arg_validation(data, mesh):
    X, y = data
    with pytest.raises(ValueError, match="pipeline="):
        fit_artifacts(X, y, FCFG, seed=0, mesh=mesh, pipeline="bogus")
    # the knob must fail loudly on the single-device path too, not be
    # silently ignored until the code first runs on a real mesh
    with pytest.raises(ValueError, match="pipeline="):
        fit_artifacts(X, y, FCFG, seed=0, mesh=None,
                      pipeline=PipelineConfig)  # the class, not an instance


def test_base_exception_joins_pipeline_threads():
    """KeyboardInterrupt-style BaseExceptions must stop and join the stage
    threads — no busy-polling daemon may outlive the fit."""
    class Boom(BaseException):
        pass

    def dispatch(inputs):
        raise Boom("simulated Ctrl-C mid-dispatch")

    def collect(res, n):  # pragma: no cover — never reached
        return {}

    before = threading.active_count()
    with pytest.raises(Boom):
        fitting._run_grid_batches_pipelined(
            dispatch, collect, [(0, 0), (1, 0)], 1, checkpoint_dir=None,
            resume=False, fingerprint={}, prefetch=lambda chunk: ("x",),
            pcfg=PipelineConfig())
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == before, "pipeline thread leaked"


def test_grid_manifest_concurrent_mark_done(tmp_path):
    """mark_done from many threads (out-of-order completion) keeps the
    manifest a consistent superset-free record of exactly the marked keys."""
    man = ckpt.GridManifest(str(tmp_path), fingerprint={"v": 1})
    keys = [(b0, 2) for b0 in range(0, 40, 2)]
    threads = [threading.Thread(target=man.mark_done, args=(k,))
               for k in keys]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fresh = ckpt.GridManifest(str(tmp_path), fingerprint={"v": 1})
    assert fresh.load_done(resume=True) == set(keys)
    # and the fingerprint refusal still works on the final file
    other = ckpt.GridManifest(str(tmp_path), fingerprint={"v": 2})
    with pytest.raises(ValueError, match="mismatched"):
        other.load_done(resume=True)


def test_int8_codes_single_device_parity(data):
    """ROADMAP item: int8_codes must engage in the single-device fit_one
    too, and quantised code storage must not change the trained forest
    (codes are exact small ints either way)."""
    X, y = data
    f32 = fit_artifacts(X, y, FCFG, seed=0)
    i8 = fit_artifacts(X, y, dataclasses.replace(FCFG, int8_codes=True),
                       seed=0)
    assert _equal(f32, i8)
