"""Training-path correctness: PRNG decorrelation, manifest safety, and
single-device vs shard_map trainer parity (subprocess: XLA_FLAGS must be set
before jax init to get the 8-virtual-device host mesh)."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.core import interpolants as itp
from repro.tabgen import fit_artifacts


def test_cfm_jitter_decorrelated_from_noise():
    """Regression: ``fit_one``/``_fit_one_sharded`` drew x1 with ``k_tr`` and
    passed the same ``k_tr`` as the CFM-jitter key, so the "independent"
    jitter was exactly ``sigma * x1``. ``sample_bridge`` must fold in a
    distinct subkey."""
    t, sigma = 0.5, 0.7
    x0 = jnp.zeros((4096, 2), jnp.float32)
    x1, xt, tgt = itp.sample_bridge(jax.random.PRNGKey(0), x0, "flow", t,
                                    sigma)
    eps = (np.asarray(xt) - t * np.asarray(x1)) / sigma   # recovered jitter
    # under the bug eps == x1 bit-for-bit (same key, same shape)
    assert not np.allclose(eps, np.asarray(x1))
    corr = np.corrcoef(np.asarray(x1).ravel(), eps.ravel())[0, 1]
    assert abs(corr) < 0.05, corr
    # the target is unaffected: flow regresses x1 - x0
    np.testing.assert_allclose(np.asarray(tgt), np.asarray(x1), rtol=1e-6)


def _small_cfg(**kw):
    base = dict(n_t=4, duplicate_k=5, n_trees=5, max_depth=3, n_bins=16,
                reg_lambda=1.0)
    base.update(kw)
    return ForestConfig(**base)


def test_resume_refuses_mismatched_batch_size(tmp_path):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(120, 3)).astype(np.float32)
    fit_artifacts(X, None, _small_cfg(), seed=0,
                  checkpoint_dir=str(tmp_path), ensembles_per_batch=2)
    with pytest.raises(ValueError, match="ensembles_per_batch"):
        fit_artifacts(X, None, _small_cfg(), seed=0,
                      checkpoint_dir=str(tmp_path), resume=True,
                      ensembles_per_batch=4)


def test_resume_refuses_mismatched_config(tmp_path):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(120, 3)).astype(np.float32)
    fit_artifacts(X, None, _small_cfg(), seed=0,
                  checkpoint_dir=str(tmp_path), ensembles_per_batch=2)
    with pytest.raises(ValueError, match="config"):
        fit_artifacts(X, None, _small_cfg(n_trees=7), seed=0,
                      checkpoint_dir=str(tmp_path), resume=True,
                      ensembles_per_batch=2)
    # matching run config still resumes bit-identically
    a1 = fit_artifacts(X, None, _small_cfg(), seed=0,
                       checkpoint_dir=str(tmp_path), resume=True,
                       ensembles_per_batch=2)
    a2 = fit_artifacts(X, None, _small_cfg(), seed=0,
                       checkpoint_dir=str(tmp_path), resume=True,
                       ensembles_per_batch=2)
    np.testing.assert_array_equal(np.asarray(a1.leaf), np.asarray(a2.leaf))


_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, shutil
import jax
import numpy as np

from repro.config import ForestConfig
from repro.eval import metrics as M
from repro.tabgen import TabularGenerator, fit_artifacts, sample

assert len(jax.devices()) == 8
rng = np.random.default_rng(1)
n_per, p = 192, 3
mu0, mu1 = np.array([-1.5, 0.0, 1.0]), np.array([1.5, 1.0, -1.0])
X = np.concatenate([mu0 + 0.4 * rng.normal(size=(n_per, p)),
                    mu1 + 0.4 * rng.normal(size=(n_per, p))]).astype(
                        np.float32)
y = np.concatenate([np.zeros(n_per), np.ones(n_per)]).astype(np.int64)
fcfg = ForestConfig(n_t=4, duplicate_k=8, n_trees=8, max_depth=3, n_bins=16,
                    reg_lambda=1.0)

def class_err(art):
    G, yg = sample(art, 2 * n_per, seed=5)
    errs = []
    for cls, mu in ((0, mu0), (1, mu1)):
        sel = yg == cls
        errs.append(float(np.abs(G[sel].mean(0) - mu).max()))
        errs.append(float(np.abs(G[sel].std(0) - 0.4).max()))
    return G, max(errs)

art_single = fit_artifacts(X, y, fcfg, seed=0)
meshes = {"1x1": jax.make_mesh((1, 1), ("data", "model")),
          "4x2": jax.make_mesh((4, 2), ("data", "model"))}
G0, err0 = class_err(art_single)
report = {"single": err0}
for name, mesh in meshes.items():
    art_m = fit_artifacts(X, y, fcfg, seed=0, mesh=mesh)
    Gm, errm = class_err(art_m)
    report[name] = errm
    report[f"{name}_w1_vs_single"] = float(M.sliced_w1(Gm, G0))

# facade + save/load round-trip through the sharded trainer
tmp = os.environ.get("TMPDIR", "/tmp") + "/parity_model"
gen = TabularGenerator(fcfg).fit(X, y, seed=0, mesh=meshes["4x2"])
base = gen.save(tmp)
G_loaded, _ = TabularGenerator.load(base).generate(2 * n_per, seed=5)
report["roundtrip_w1_vs_single"] = float(M.sliced_w1(G_loaded, G0))

# resume mid-grid: a fresh dir seeded with only the first batch of a full
# checkpointed run must finish the remaining batches to identical forests
ck_full, ck_part = "/tmp/ck_full", "/tmp/ck_part"
for d in (ck_full, ck_part):
    shutil.rmtree(d, ignore_errors=True)
art_full = fit_artifacts(X, y, fcfg, seed=0, mesh=meshes["4x2"],
                         checkpoint_dir=ck_full, ensembles_per_batch=4)
os.makedirs(ck_part)
shutil.copy(ck_full + "/batch_0.npz", ck_part)
with open(ck_full + "/manifest.json") as f:
    man = json.load(f)
man["batches"] = [b for b in man["batches"] if b[0] == 0]
with open(ck_part + "/manifest.json", "w") as f:
    json.dump(man, f)
art_res = fit_artifacts(X, y, fcfg, seed=0, mesh=meshes["4x2"],
                        checkpoint_dir=ck_part, resume=True,
                        ensembles_per_batch=4)
report["resume_equal"] = bool(
    np.array_equal(np.asarray(art_full.leaf), np.asarray(art_res.leaf))
    and np.array_equal(np.asarray(art_full.feat), np.asarray(art_res.feat)))

# elastic resume: a different mesh shape with no pinned batch size inherits
# the manifest's ensembles_per_batch instead of refusing on the fingerprint
mesh24 = jax.make_mesh((2, 4), ("data", "model"))
art_el = fit_artifacts(X, y, fcfg, seed=0, mesh=mesh24,
                       checkpoint_dir=ck_full, resume=True)
report["elastic_equal"] = bool(
    np.array_equal(np.asarray(art_full.leaf), np.asarray(art_el.leaf)))

# pipelined vs serial on the 4x2 mesh: the double-buffered loop must be
# bit-exact against the serial PR-2 loop for the same seed and batch size
from repro.tabgen import PipelineConfig
art_ser = fit_artifacts(X, y, fcfg, seed=0, mesh=meshes["4x2"],
                        ensembles_per_batch=4, pipeline=None)
art_pipe = fit_artifacts(X, y, fcfg, seed=0, mesh=meshes["4x2"],
                         ensembles_per_batch=4,
                         pipeline=PipelineConfig(prefetch_depth=2))
report["pipe_bitexact"] = all(
    np.array_equal(np.asarray(getattr(art_ser, f)),
                   np.asarray(getattr(art_pipe, f)))
    for f in ("feat", "thr_val", "leaf", "best_round", "rounds_run",
              "val_curve"))
report["ok"] = True
print(json.dumps(report))
"""


@pytest.mark.slow
@pytest.mark.distributed
def test_sharded_trainer_parity_and_resume_8dev():
    out = subprocess.run([sys.executable, "-c", _PARITY],
                         capture_output=True, text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["ok"]
    # each trainer recovers the class structure...
    for k in ("single", "1x1", "4x2"):
        assert r[k] < 0.5, r
    # ...and the sharded samples match the single-device ones in
    # distribution (keys differ per shard, so compare statistically)
    for k in ("1x1_w1_vs_single", "4x2_w1_vs_single",
              "roundtrip_w1_vs_single"):
        assert r[k] < 0.35, r
    assert r["resume_equal"], r
    assert r["elastic_equal"], r
    assert r["pipe_bitexact"], r
