"""Unit tests for the JAX GBDT substrate + ForestFlow/ForestDiffusion core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ForestConfig
from repro.core.forest_flow import ForestGenerativeModel, weighted_edges
from repro.core import interpolants as itp
from repro.forest.binning import edges_with_sentinel, fit_bins, transform
from repro.forest.boosting import fit_boosted, fit_ensemble
from repro.forest.hist import build_histogram
from repro.forest.split import best_splits
from repro.forest.tree import grow_tree, predict_tree_codes, predict_tree_values


def _edges_codes(x, n_bins):
    e = fit_bins(jnp.asarray(x), n_bins)
    return e, transform(jnp.asarray(x), e)


def test_binning_roundtrip_semantics():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 3)).astype(np.float32)
    edges, codes = _edges_codes(x, 16)
    codes = np.asarray(codes)
    assert codes.min() >= 0 and codes.max() <= 15
    # code > b  <=>  x > edges[b]
    e = np.asarray(edges)
    for b in range(15):
        np.testing.assert_array_equal(codes[:, 0] > b, x[:, 0] > e[0, b])


def test_histogram_totals_match():
    rng = np.random.default_rng(1)
    n, p, out, n_bins, n_nodes = 300, 4, 2, 8, 4
    codes = jnp.asarray(rng.integers(0, n_bins, (n, p)))
    node_id = jnp.asarray(rng.integers(0, n_nodes, (n,)), jnp.int32)
    g = jnp.asarray(rng.normal(size=(n, out)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1.0, n).astype(np.float32))
    sums, cnt = build_histogram(codes, node_id, g, w, n_nodes, n_bins)
    np.testing.assert_allclose(np.asarray(jnp.sum(sums, axis=(0, 2))),
                               np.asarray((g * w[:, None]).sum(0))[None].repeat(p, 0),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.sum(cnt, axis=(0, 2))),
                               np.full((p,), float(np.asarray(w).sum())),
                               rtol=1e-5)


def test_single_tree_learns_step_function():
    """Depth-1 tree on y = 1[x > 0] must split near 0 and hit both leaves."""
    rng = np.random.default_rng(2)
    x = rng.uniform(-1, 1, size=(2000, 1)).astype(np.float32)
    y = (x > 0).astype(np.float32)
    edges, codes = _edges_codes(x, 32)
    g = (jnp.zeros_like(jnp.asarray(y)) - jnp.asarray(y))  # g = pred - y
    w = jnp.ones((2000,), jnp.float32)
    tree, node_id = grow_tree(codes, g, w, edges_with_sentinel(edges),
                              depth=1, n_bins=32, reg_lambda=0.0,
                              min_child_weight=1.0, learning_rate=1.0)
    pred = np.asarray(predict_tree_values(jnp.asarray(x), tree.feat,
                                          tree.thr_val, tree.leaf, 1))
    # leaf values approx 0 and 1 on each side
    assert abs(pred[x[:, 0] > 0.05].mean() - 1.0) < 0.05
    assert abs(pred[x[:, 0] < -0.05].mean()) < 0.05
    assert abs(float(tree.thr_val[0])) < 0.1  # split close to 0


def test_codes_vs_values_prediction_agree():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(400, 6)).astype(np.float32)
    y = np.sin(x[:, 0]) + x[:, 1] ** 2
    edges, codes = _edges_codes(x, 16)
    g = -jnp.asarray(y[:, None].astype(np.float32))
    w = jnp.ones((400,), jnp.float32)
    tree, _ = grow_tree(codes, g, w, edges_with_sentinel(edges), depth=4,
                        n_bins=16, reg_lambda=1.0, min_child_weight=1.0,
                        learning_rate=0.5)
    by_codes = np.asarray(predict_tree_codes(codes, tree, 4))
    by_vals = np.asarray(predict_tree_values(jnp.asarray(x), tree.feat,
                                             tree.thr_val, tree.leaf, 4))
    np.testing.assert_allclose(by_codes, by_vals, rtol=1e-6)


def test_boosting_fits_regression_target():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(1000, 4)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + 0.5 * x[:, 1]).astype(np.float32)[:, None]
    edges, codes = _edges_codes(x, 32)
    w = jnp.ones((1000,), jnp.float32)
    fcfg = ForestConfig(n_trees=40, max_depth=4, learning_rate=0.3,
                        n_bins=32, reg_lambda=1.0)
    res = fit_boosted(codes, jnp.asarray(y), w, edges_with_sentinel(edges),
                      codes, jnp.asarray(y), w, fcfg)
    # training-as-validation loss should drop far below the variance of y
    final = float(res.val_curve[int(res.rounds_run) - 1])
    assert final < 0.05 * float(np.var(y))


def test_early_stopping_masks_trees_and_stops():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(400, 3)).astype(np.float32)
    y = x[:, :1].astype(np.float32)
    noise = rng.normal(size=(400, 1)).astype(np.float32)
    edges, codes = _edges_codes(x, 16)
    w = jnp.ones((400,), jnp.float32)
    fcfg = ForestConfig(n_trees=60, max_depth=3, learning_rate=0.3, n_bins=16,
                        early_stop_rounds=5, reg_lambda=1.0)
    # validation target is pure noise -> must stop early
    res = fit_boosted(codes, jnp.asarray(y), w, edges_with_sentinel(edges),
                      codes, jnp.asarray(noise), w, fcfg)
    assert int(res.rounds_run) < 60
    assert int(res.best_round) <= int(res.rounds_run)
    leaves_after = np.asarray(res.leaf)[int(res.best_round) + 1:]
    assert np.all(leaves_after == 0.0)


def test_so_vs_mo_shapes_and_single_output_equivalence():
    rng = np.random.default_rng(6)
    x = rng.normal(size=(300, 3)).astype(np.float32)
    y = np.stack([x[:, 0], x[:, 1] * 2], 1).astype(np.float32)
    edges, codes = _edges_codes(x, 16)
    w = jnp.ones((300,), jnp.float32)
    so = ForestConfig(n_trees=10, max_depth=3, n_bins=16, multi_output=False,
                      reg_lambda=1.0)
    mo = ForestConfig(n_trees=10, max_depth=3, n_bins=16, multi_output=True,
                      reg_lambda=1.0)
    r_so = fit_ensemble(codes, jnp.asarray(y), w, edges_with_sentinel(edges),
                        codes, jnp.asarray(y), w, so)
    r_mo = fit_ensemble(codes, jnp.asarray(y), w, edges_with_sentinel(edges),
                        codes, jnp.asarray(y), w, mo)
    assert r_so.feat.shape == (2, 10, 7)
    assert r_so.leaf.shape == (2, 10, 8, 1)
    assert r_mo.feat.shape == (1, 10, 7)
    assert r_mo.leaf.shape == (1, 10, 8, 2)
    # with a single output, SO and MO coincide exactly
    y1 = y[:, :1]
    r1 = fit_ensemble(codes, jnp.asarray(y1), w, edges_with_sentinel(edges),
                      codes, jnp.asarray(y1), w, so)
    r2 = fit_ensemble(codes, jnp.asarray(y1), w, edges_with_sentinel(edges),
                      codes, jnp.asarray(y1), w, mo)
    np.testing.assert_allclose(np.asarray(r1.leaf[0]), np.asarray(r2.leaf[0]),
                               rtol=1e-5)


def test_weighted_edges_ignore_padded_rows():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(200, 2)).astype(np.float32)
    x_pad = np.concatenate([x, np.full((100, 2), 1e6, np.float32)])
    w = np.concatenate([np.ones(200), np.zeros(100)]).astype(np.float32)
    e_ref = np.asarray(fit_bins(jnp.asarray(x), 8))
    e_pad = np.asarray(weighted_edges(jnp.asarray(x_pad), jnp.asarray(w), 8))
    np.testing.assert_allclose(e_pad, e_ref, atol=0.15)


@pytest.mark.parametrize("method", ["flow", "diffusion"])
def test_end_to_end_recovers_gaussian_mixture(method):
    """The paper's core claim in miniature: the forest generative model learns
    a 2-class, 3-feature distribution well enough to match per-class moments."""
    rng = np.random.default_rng(8)
    n_per = 300
    mu0, mu1 = np.array([-2.0, 0.0, 1.0]), np.array([2.0, 1.0, -1.0])
    X = np.concatenate([
        mu0 + 0.5 * rng.normal(size=(n_per, 3)),
        mu1 + 0.5 * rng.normal(size=(n_per, 3)),
    ]).astype(np.float32)
    y = np.concatenate([np.zeros(n_per), np.ones(n_per)]).astype(np.int64)
    fcfg = ForestConfig(method=method, n_t=12, duplicate_k=20, n_trees=25,
                        max_depth=4, learning_rate=0.3, n_bins=32,
                        reg_lambda=1.0)
    model = ForestGenerativeModel(fcfg).fit(X, y, seed=0)
    Xg, yg = model.generate(600, seed=1)
    assert Xg.shape == (600, 3)
    for cls, mu in [(0, mu0), (1, mu1)]:
        sel = yg == cls
        assert sel.sum() > 200  # label sampler keeps the 50/50 split
        got = Xg[sel].mean(axis=0)
        np.testing.assert_allclose(got, mu, atol=0.5)
        assert np.all(Xg[sel].std(axis=0) < 1.2)


def test_checkpoint_resume(tmp_path):
    rng = np.random.default_rng(9)
    X = rng.normal(size=(120, 3)).astype(np.float32)
    fcfg = ForestConfig(n_t=4, duplicate_k=5, n_trees=5, max_depth=3,
                        n_bins=16, reg_lambda=1.0)
    m1 = ForestGenerativeModel(fcfg).fit(
        X, seed=0, checkpoint_dir=str(tmp_path), ensembles_per_batch=2)
    # resume must reload identical forests without retraining
    m2 = ForestGenerativeModel(fcfg).fit(
        X, seed=123, checkpoint_dir=str(tmp_path), resume=True,
        ensembles_per_batch=2)
    np.testing.assert_array_equal(m1.forests["leaf"], m2.forests["leaf"])


def test_vp_interpolant_matches_eq2():
    # x_t ~ N(sqrt(1-sigma^2) x0, sigma^2) with alpha = sqrt(1 - sigma^2)
    t = jnp.float32(0.5)
    a, s = itp.vp_alpha_sigma(t)
    np.testing.assert_allclose(float(a ** 2 + s ** 2), 1.0, rtol=1e-5)
    x0 = jnp.ones((4, 2))
    x1 = jnp.zeros((4, 2))
    xt, tgt = itp.make_xt_target("diffusion", x0, x1, t)
    np.testing.assert_allclose(np.asarray(xt), float(a) * np.ones((4, 2)),
                               rtol=1e-5)


def test_imputation_fills_consistent_values():
    """Impute a masked feature on correlated data: x1 ~= 2*x0; the imputed
    x1 must track the observed x0 (joint structure, not the marginal)."""
    rng = np.random.default_rng(11)
    n = 400
    x0c = rng.normal(size=(n, 1)).astype(np.float32)
    X = np.concatenate([x0c, 2 * x0c + 0.1 * rng.normal(size=(n, 1)).astype(np.float32)], 1)
    fcfg = ForestConfig(method="flow", n_t=12, duplicate_k=20, n_trees=30,
                        max_depth=4, n_bins=32, reg_lambda=1.0)
    model = ForestGenerativeModel(fcfg).fit(X, seed=0)
    Xm = X[:50].copy()
    Xm[:, 1] = np.nan
    filled = model.impute(Xm, seed=3, refine_rounds=5)
    assert not np.isnan(filled).any()
    # observed column untouched
    np.testing.assert_array_equal(filled[:, 0], X[:50, 0])
    # imputed column correlates strongly with 2*x0
    corr = np.corrcoef(filled[:, 1], 2 * X[:50, 0])[0, 1]
    assert corr > 0.8, corr
